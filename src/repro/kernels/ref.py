"""Pure-jnp oracles for every Pallas kernel (and the CPU execution path of
the model zoo — models and kernels share exactly these semantics).

All functions are jit-compatible, fp32-accumulating, and shaped:

  gemm_ref           : (M, K) @ (K, N) -> (M, N)
  attention_ref      : q (B, Hq, Tq, D), k/v (B, Hkv, Tk, D) -> (B, Hq, Tq, D)
                       causal / sliding-window / logit-softcap / GQA
  decode_attention_ref: q (B, Hq, 1, D) over a KV cache (B, Hkv, S, D)
  selective_scan_ref : Mamba-style diagonal SSM scan
  rwkv6_ref          : RWKV-6 (Finch) wkv recurrence with data-dependent decay
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(a.dtype)


def _mask(tq: int, tk: int, *, causal: bool, window: int | None,
          offset: int = 0) -> jax.Array:
    """(tq, tk) boolean mask. ``offset`` = absolute position of q row 0 minus
    k col 0 (for decode: offset = S - 1)."""
    qpos = jnp.arange(tq)[:, None] + offset
    kpos = jnp.arange(tk)[None, :]
    m = jnp.ones((tq, tk), dtype=bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  softcap: float | None = None, scale: float | None = None,
                  offset: int = 0) -> jax.Array:
    """Grouped-query attention without materializing repeated KV: q is
    reshaped to (B, Hkv, G, Tq, D) and contracted against the shared KV —
    a ``jnp.repeat`` here would force GSPMD to reshard/replicate the whole
    (possibly sequence-sharded) cache."""
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    # bf16 inputs stay bf16 with fp32 accumulation (a full .astype(f32) on a
    # sequence-sharded KV cache makes XLA materialize an f32 copy of the
    # whole cache); fp32 inputs keep exact-f32 math for the kernel oracles.
    lowp = q.dtype == jnp.bfloat16
    cast = (lambda t: t) if lowp else (lambda t: t.astype(jnp.float32))
    qg = cast(q).reshape(B, Hkv, g, Tq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, cast(k),
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    m = _mask(Tq, Tk, causal=causal, window=window, offset=offset)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(k.dtype) if lowp else p,
                   cast(v), preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, Tq, D).astype(q.dtype)


def chunked_attention_ref(q, k, v, *, causal: bool = True,
                          window: int | None = None,
                          softcap: float | None = None,
                          scale: float | None = None,
                          kv_chunk: int = 1024) -> jax.Array:
    """Flash-style streaming attention in pure jnp: lax.scan over KV chunks
    with running (max, sum, acc) — O(T·chunk) score memory instead of O(T²).

    This is the LEGO score-stationary dataflow expressed at the XLA level
    (the Pallas kernel's exact algorithm, compilable on any backend); it is
    the "beyond-paper" memory optimization used by the §Perf loop for the
    long-sequence training/prefill cells.  Numerics: same streaming-softmax
    recurrence as the kernel; bf16 operands keep f32 accumulation.
    """
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kv_chunk = min(kv_chunk, Tk)
    assert Tk % kv_chunk == 0
    n_chunks = Tk // kv_chunk
    lowp = q.dtype == jnp.bfloat16
    cast = (lambda t: t) if lowp else (lambda t: t.astype(jnp.float32))

    qg = cast(q).reshape(B, Hkv, g, Tq, D)
    ks = cast(k).reshape(B, Hkv, n_chunks, kv_chunk, D).swapaxes(0, 2)
    vs = cast(v).reshape(B, Hkv, n_chunks, kv_chunk, D).swapaxes(0, 2)
    qpos = jnp.arange(Tq)

    def step(carry, inp):
        m, l, acc, ci = carry
        kc, vc = inp  # (Hkv, B, kv_chunk, D) after swap — fix axes below
        kc = kc.swapaxes(0, 1)
        vc = vc.swapaxes(0, 1)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = ci * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((Tq, kv_chunk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bhkd->bhgqd",
                        p.astype(kc.dtype) if lowp else p, vc,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc, ci + 1), None

    m0 = jnp.full((B, Hkv, g, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Tq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, Tq, D), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, jnp.int32(0)),
                                     (ks, vs))
    l = jnp.where(l == 0.0, 1.0, l)
    out = acc / l[..., None]
    return out.reshape(B, Hq, Tq, D).astype(q.dtype)


def decode_attention_ref(q, k, v, *, window: int | None = None,
                         softcap: float | None = None,
                         scale: float | None = None,
                         pos: int | jax.Array | None = None) -> jax.Array:
    """One-token decode: q (B, Hq, 1, D), cache (B, Hkv, S, D).  ``pos`` is
    the query's absolute position (cache entries beyond it are masked); with
    a full cache pos = S-1."""
    B, Hq, Tq, Dh = q.shape
    _, Hkv, S, _ = k.shape
    g = Hq // Hkv
    if pos is None:
        pos = S - 1
    sc = scale if scale is not None else Dh ** -0.5
    lowp = q.dtype == jnp.bfloat16
    cast = (lambda t: t) if lowp else (lambda t: t.astype(jnp.float32))
    qg = cast(q).reshape(B, Hkv, g * Tq, Dh)
    s = jnp.einsum("bhqd,bhkd->bhqk", qg, cast(k),
                   preferred_element_type=jnp.float32) * sc
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(S)
    m = kpos <= pos
    if window is not None:
        m &= kpos > pos - window
    s = jnp.where(m[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(k.dtype) if lowp else p,
                   cast(v), preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, Tq, Dh).astype(q.dtype)


def selective_scan_ref(x, dt, A, B, C, D_skip, h0=None):
    """Mamba-style diagonal selective scan (S6, real A < 0).

    x (Bt, L, Dm), dt (Bt, L, Dm) [post-softplus], A (Dm, N), B/C (Bt, L, N),
    D_skip (Dm,).  Returns (y (Bt, L, Dm), h_last (Bt, Dm, N)).
    """
    in_dtype = x.dtype
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * Af[None, None])         # (Bt, L, Dm, N)
    dBx = dt[..., None] * Bf[:, :, None, :] * x[..., None]

    def combine(a, b):
        (ga, xa), (gb, xb) = a, b
        return ga * gb, xa * gb + xb

    if h0 is not None:
        dBx = dBx.at[:, 0].add(dA[:, 0] * h0.astype(jnp.float32))
    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bldn,bln->bld", h, Cf)
    y = y + x * D_skip.astype(jnp.float32)[None, None]
    return y.astype(in_dtype), h[:, -1]


def chunked_selective_scan_ref(x, dt, A, B, C, D_skip, chunk: int = 256):
    """Chunked SSM scan: lax.scan over sequence chunks carrying h, each
    chunk rematerialized (jax.checkpoint) — backward memory drops from
    O(L·Dm·N) to O((L/chunk)·Dm·N) carries + one in-flight chunk.  Matches
    the Pallas kernel's chunking (DESIGN.md §2)."""
    Bt, L, Dm = x.shape
    N = A.shape[1]
    chunk = min(chunk, L)
    assert L % chunk == 0
    n = L // chunk

    def body(h, inp):
        xc, dtc, Bc, Cc = inp
        y, h2 = selective_scan_ref(xc, dtc, A, Bc, Cc, D_skip, h0=h)
        return h2, y

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (x.reshape(Bt, n, chunk, Dm).swapaxes(0, 1),
          dt.reshape(Bt, n, chunk, Dm).swapaxes(0, 1),
          B.reshape(Bt, n, chunk, N).swapaxes(0, 1),
          C.reshape(Bt, n, chunk, N).swapaxes(0, 1))
    h0 = jnp.zeros((Bt, Dm, N), jnp.float32)
    h, ys = jax.lax.scan(body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(Bt, L, Dm)
    return y, h


def chunked_rwkv6_ref(r, k, v, w, u, chunk: int = 256):
    """Chunked RWKV-6: sequence chunks with the (Dk, Dv) state carried and
    chunk bodies rematerialized (same memory argument as the SSM scan)."""
    Bb, H, T, Dk = r.shape
    Dv = v.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    n = T // chunk

    def body(S, inp):
        rc, kc, vc, wc = inp
        o, S2 = rwkv6_ref(rc, kc, vc, wc, u, s0=S)
        return S2, o

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = tuple(t.reshape(Bb, H, n, chunk, t.shape[-1]).transpose(2, 0, 1, 3, 4)
               for t in (r, k, v, w))
    S0 = jnp.zeros((Bb, H, Dk, Dv), jnp.float32)
    S, os_ = jax.lax.scan(body, S0, xs)
    o = os_.transpose(1, 2, 0, 3, 4).reshape(Bb, H, T, Dv)
    return o, S


def rwkv6_ref(r, k, v, w, u, s0=None):
    """RWKV-6 (Finch) wkv: per head, state S (Dk, Dv):

        o_t = rᵗ · (S + diag(u) kᵗ vᵗᵀ)
        S   = diag(w_t) S + kᵗ vᵗᵀ            (w_t data-dependent, in (0,1))

    r/k/w (B, H, T, Dk), v (B, H, T, Dv), u (H, Dk).
    Returns (o (B, H, T, Dv), S_last (B, H, Dk, Dv)).
    """
    B, H, T, Dk = r.shape
    Dv = v.shape[-1]
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)
    S = (jnp.zeros((B, H, Dk, Dv), jnp.float32) if s0 is None
         else s0.astype(jnp.float32))

    def step(S, inp):
        rt, kt, vt, wt = inp  # (B, H, Dk) / (B, H, Dv)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,Dk,Dv)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + uf[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = (jnp.moveaxis(rf, 2, 0), jnp.moveaxis(kf, 2, 0),
          jnp.moveaxis(vf, 2, 0), jnp.moveaxis(wf, 2, 0))
    S, outs = jax.lax.scan(step, S, xs)
    return jnp.moveaxis(outs, 0, 2).astype(r.dtype), S
