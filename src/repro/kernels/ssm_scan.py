"""Chunked selective-scan (Mamba S6) Pallas kernel.

The recurrence ``h ← exp(Δ·A)·h + Δ·B·x`` is *not* an affine MAC loop nest,
so LEGO's interconnect generation does not apply (DESIGN.md §4 — noted
inapplicability for SSM blocks); the kernel instead follows the TPU-native
chunking pattern: the sequence is cut into VMEM-sized chunks, the state
``h (bd, N)`` lives in VMEM scratch and is carried across the innermost
("arbitrary") grid dimension, and each chunk runs a register-level
``fori_loop``.

Grid (B, Dm/bd, L/bl); blocks: x/dt (1, bl, bd), B/C (1, bl, N), A (bd, N).
Outputs: y (B, L, Dm) and the final state h (B, Dm, N) — the state handoff
used by decode and by sequence-parallel sharding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _scan_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, h_out_ref,
                 h_ref, *, bl: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = A_ref[...].astype(jnp.float32)          # (bd, N)
    Dskip = D_ref[...].astype(jnp.float32)      # (1, bd)

    def step(l, _):
        x = x_ref[0, l].astype(jnp.float32)     # (bd,)
        dt = dt_ref[0, l].astype(jnp.float32)   # (bd,)
        Bt = B_ref[0, l].astype(jnp.float32)    # (N,)
        Ct = C_ref[0, l].astype(jnp.float32)    # (N,)
        dA = jnp.exp(dt[:, None] * A)           # (bd, N)
        h = dA * h_ref[...] + (dt * x)[:, None] * Bt[None, :]
        h_ref[...] = h
        y = h @ Ct + x * Dskip[0]
        y_ref[0, l] = y.astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, bl, step, ())

    @pl.when(ci == pl.num_programs(2) - 1)
    def _done():
        h_out_ref[0] = h_ref[...].astype(h_out_ref.dtype)


def ssm_scan_pallas(x, dt, A, B, C, D, *, bd: int, bl: int,
                    interpret: bool = False):
    """x/dt (Bt, L, Dm), A (Dm, N), B/C (Bt, L, N), D (Dm,).
    Returns (y (Bt, L, Dm), h_last (Bt, Dm, N))."""
    Bt, L, Dm = x.shape
    N = A.shape[1]
    assert Dm % bd == 0 and L % bl == 0
    grid = (Bt, Dm // bd, L // bl)
    y, h = pl.pallas_call(
        functools.partial(_scan_kernel, bl=bl),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bl, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, bl, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((bd, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, bl, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, bl, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, bd), lambda b, d, c: (0, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, bl, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, bd, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, L, Dm), x.dtype),
            jax.ShapeDtypeStruct((Bt, Dm, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B, C, D.reshape(1, -1))
    return y, h
