"""RWKV-6 (Finch) wkv recurrence Pallas kernel.

Data-dependent per-channel decay makes this a gated linear recurrence (not an
affine loop nest — see DESIGN.md §4); the TPU-native structure mirrors
:mod:`repro.kernels.ssm_scan`: per-(batch, head) state matrix ``S (Dk, Dv)``
resident in VMEM scratch, sequence chunked over the innermost grid dim, a
``fori_loop`` of rank-1 updates inside each chunk:

    o_t = r_t · (S + diag(u)·k_t v_tᵀ)
    S   = diag(w_t)·S + k_t v_tᵀ
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref, s_ref,
                 *, bt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)  # (Dk,)

    def step(t, _):
        r = r_ref[0, 0, t].astype(jnp.float32)  # (Dk,)
        k = k_ref[0, 0, t].astype(jnp.float32)  # (Dk,)
        v = v_ref[0, 0, t].astype(jnp.float32)  # (Dv,)
        w = w_ref[0, 0, t].astype(jnp.float32)  # (Dk,)
        kv = k[:, None] * v[None, :]            # (Dk, Dv)
        out = r @ (s_ref[...] + u[:, None] * kv)
        o_ref[0, 0, t] = out.astype(o_ref.dtype)
        s_ref[...] = w[:, None] * s_ref[...] + kv
        return ()

    jax.lax.fori_loop(0, bt, step, ())

    @pl.when(ti == pl.num_programs(2) - 1)
    def _done():
        s_out_ref[0, 0] = s_ref[...].astype(s_out_ref.dtype)


def rwkv6_pallas(r, k, v, w, u, *, bt: int, interpret: bool = False):
    """r/k/w (B, H, T, Dk), v (B, H, T, Dv), u (H, Dk).
    Returns (o (B, H, T, Dv), S_last (B, H, Dk, Dv))."""
    B, H, T, Dk = r.shape
    Dv = v.shape[-1]
    assert T % bt == 0
    grid = (B, H, T // bt)
    o, s = pl.pallas_call(
        functools.partial(_rwkv_kernel, bt=bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bt, Dk), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt, Dk), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt, Dv), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt, Dk), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, Dk), lambda b, h, t: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bt, Dv), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, Dk, Dv), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, Dv), r.dtype),
            jax.ShapeDtypeStruct((B, H, Dk, Dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
    return o, s
