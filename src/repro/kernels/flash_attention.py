"""Streaming-softmax attention Pallas kernel (FlashAttention-style, TPU).

In LEGO terms this is the fused two-dataflow attention design of Fig. 10:
the QKᵀ stage and the PV stage share the score tile *in place* (score-
stationary — the S/P tensor never leaves VMEM), and the softmax runs on the
"PPU" (the VPU) between the two MXU stages.  Supports:

  * causal masking with an absolute-position ``offset`` (decode reuses the
    same kernel with Tq = 1, offset = S − 1),
  * sliding-window attention (Mistral/Gemma-2 local layers),
  * logit soft-capping (Gemma-2),
  * GQA: the kv-head BlockSpec index map folds the query-group division —
    no materialized KV repeat.

Grid (B, Hq, Tq/bq, Tk/bk), kv innermost; running (m, l, acc) in VMEM
scratch; fully-masked kv blocks are skipped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30
STATE_LANES = 128  # TPU-friendly lane width for the (m, l) state tiles


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float | None, offset: int, bq: int, bk: int):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq + offset
    k_start = ki * bk
    needed = True
    if causal:
        needed = k_start <= q_start + bq - 1
    if window is not None:
        needed = jnp.logical_and(needed, k_start + bk > q_start - window + 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + jnp.sum(
            p, axis=-1, keepdims=True) * jnp.ones_like(l_ref)
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new * jnp.ones_like(m_ref)

    @pl.when(ki == pl.num_programs(3) - 1)
    def _done():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    bq: int, bk: int, causal: bool = True, window: int | None = None,
    softcap: float | None = None, scale: float | None = None,
    offset: int = 0, interpret: bool = False,
) -> jax.Array:
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    assert Tq % bq == 0 and Tk % bk == 0
    scale = scale if scale is not None else D ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, offset=offset, bq=bq, bk=bk)
    grid = (B, Hq, Tq // bq, Tk // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, STATE_LANES), jnp.float32),  # running max
            pltpu.VMEM((bq, STATE_LANES), jnp.float32),  # running sum
            pltpu.VMEM((bq, D), jnp.float32),            # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
