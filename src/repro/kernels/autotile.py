"""LEGO-derived BlockSpec tile selection for the TPU kernels.

This is the paper's front end re-targeted at the TPU memory hierarchy
(DESIGN.md §2): the MXU plays the role of the generated FU array (a GEMM-JK
design with c = [1,1] *is* the MXU), HBM→VMEM tiling plays the role of the
data-distribution switches, and the banking inequality (Eq. 9) becomes a
VMEM working-set budget.  Tile selection maximizes arithmetic intensity
(reuse) subject to:

  * working set  (bm·bk + bk·bn + bm·bn)·bytes ≤ VMEM budget,
  * MXU alignment: tiles are multiples of (8, 128) for fp32 / (16, 128) for
    bf16 — the systolic array's native lane/sublane shape,
  * the grid covers the problem exactly (pad-to-tile handled by callers).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

VMEM_BYTES = 96 * 1024 * 1024 // 8  # ~12 MB usable of 16 MB v5e VMEM
LANE = 128


def _sublane(dtype_bytes: int) -> int:
    return max(8, 32 // dtype_bytes)


def _align(x: int, m: int) -> int:
    return max(m, (x // m) * m)


@dataclass(frozen=True)
class GemmTiles:
    bm: int
    bn: int
    bk: int

    @property
    def vmem_bytes(self) -> int:
        return 4 * (self.bm * self.bk + self.bk * self.bn + self.bm * self.bn)


def gemm_tiles(M: int, N: int, K: int, dtype_bytes: int = 2,
               vmem_budget: int = VMEM_BYTES) -> GemmTiles:
    """Pick (bm, bn, bk) maximizing reuse within the VMEM budget.

    Arithmetic intensity of a (bm, bn, bk) step is
    ``bm·bn·bk / (bm·bk + bk·bn + bm·bn)`` — maximized by square-ish tiles,
    i.e. exactly the banking-style balance condition of Eq. 9 applied to the
    HBM→VMEM level.
    """
    sub = _sublane(dtype_bytes)
    best, best_ai = None, -1.0
    for bm in (sub, 128, 256, 512):
        if bm > max(sub, M):
            continue
        for bn in (LANE, 256, 512, 1024):
            if bn > max(LANE, N):
                continue
            for bk in (LANE, 256, 512, 1024, 2048):
                if bk > max(LANE, K):
                    continue
                ws = dtype_bytes * (bm * bk + bk * bn) + 4 * bm * bn
                if ws > vmem_budget:
                    continue
                ai = (bm * bn * bk) / (bm * bk + bk * bn + bm * bn)
                # prefer full-problem coverage with fewer ragged tiles
                waste = (np.ceil(M / bm) * bm / max(M, 1)
                         * np.ceil(N / bn) * bn / max(N, 1))
                score = ai / waste
                if score > best_ai:
                    best_ai, best = score, GemmTiles(bm, bn, bk)
    assert best is not None
    return best


def attention_tiles(Tq: int, Tk: int, D: int, dtype_bytes: int = 2,
                    vmem_budget: int = VMEM_BYTES) -> tuple[int, int]:
    """(bq, bk) for streaming attention: score tile bq×bk plus q/k/v tiles
    must fit; softmax state is O(bq)."""
    best, best_ai = (128, 128), -1.0
    sub = _sublane(dtype_bytes)
    for bq in (sub, 128, 256, 512):
        if bq > max(sub, Tq):
            continue
        for bk in (LANE, 256, 512, 1024):
            if bk > max(LANE, Tk):
                continue
            ws = dtype_bytes * (bq * D + 2 * bk * D) + 4 * (bq * bk + 2 * bq * D)
            if ws > vmem_budget:
                continue
            ai = (bq * bk * D) / (bq * D + bk * D + bq * bk)
            if ai > best_ai:
                best_ai, best = ai, (bq, bk)
    return best
