"""Tiled GEMM Pallas kernel — the TPU realization of LEGO's GEMM-JK design.

The MXU *is* the generated systolic FU array (c = [1,1]); this kernel
supplies the two outer memory levels LEGO generates around it: the grid is
the temporal loop nest (M_T→I) and the BlockSpecs are the data-distribution
switches.  Tile sizes come from :mod:`repro.kernels.autotile` (the banking /
working-set inequality applied to VMEM).

Grid (M/bm, N/bn, K/bk) with K innermost ("arbitrary" semantics): the fp32
accumulator tile stays resident in VMEM across the K sweep — the Y-revisit
stationary reuse the front end derives for GEMM (Δt on the k-tile loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_pallas(x: jax.Array, w: jax.Array, *, bm: int, bn: int, bk: int,
                interpret: bool = False) -> jax.Array:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"pad to tiles first: {(M, N, K)} vs {(bm, bn, bk)}"
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
