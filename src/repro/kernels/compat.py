"""JAX version compatibility shims for the Pallas TPU kernels.

The compiler-params dataclass was renamed ``TPUCompilerParams`` →
``CompilerParams`` across JAX releases; resolve whichever this install has.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
