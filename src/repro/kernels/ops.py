"""Public kernel API: jit'd wrappers around the Pallas kernels.

Each op pads to the LEGO-derived tile shapes (autotile), invokes the Pallas
kernel, and unpads.  ``backend`` selects:

  * "pallas"    — pallas_call targeting TPU (interpret=False),
  * "interpret" — pallas_call in interpret mode (CPU validation),
  * "ref"       — the pure-jnp oracle (used by models on CPU and by the
                  multi-pod dry-run, whose HLO must lower on any backend).

Default: "pallas" on TPU, "ref" elsewhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as R
from .autotile import attention_tiles, gemm_tiles
from .flash_attention import flash_attention_pallas
from .gemm import gemm_pallas
from .rwkv6 import rwkv6_pallas
from .ssm_scan import ssm_scan_pallas

__all__ = ["gemm", "flash_attention", "decode_attention", "ssm_scan", "rwkv6",
           "default_backend"]


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pad_to(x, mults):
    pads = []
    needs = False
    for dim, m in zip(x.shape, mults):
        p = (-dim) % m
        pads.append((0, p))
        needs = needs or p
    return jnp.pad(x, pads) if needs else x


def gemm(x: jax.Array, w: jax.Array, backend: str | None = None) -> jax.Array:
    backend = backend or default_backend()
    if backend == "ref":
        return R.gemm_ref(x, w)
    M, K = x.shape
    _, N = w.shape
    t = gemm_tiles(M, N, K, x.dtype.itemsize)
    xp = _pad_to(x, (t.bm, t.bk))
    wp = _pad_to(w, (t.bk, t.bn))
    out = gemm_pallas(xp, wp, bm=t.bm, bn=t.bn, bk=t.bk,
                      interpret=(backend == "interpret"))
    return out[:M, :N]


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, offset: int = 0,
                    backend: str | None = None) -> jax.Array:
    backend = backend or default_backend()
    if backend == "ref":
        return R.attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale, offset=offset)
    B, Hq, Tq, D = q.shape
    Tk = k.shape[2]
    bq, bk = attention_tiles(Tq, Tk, D, q.dtype.itemsize)
    bq, bk = min(bq, Tq), min(bk, Tk)
    qp = _pad_to(q, (1, 1, bq, 1))
    kp = _pad_to(k, (1, 1, bk, 1))
    vp = _pad_to(v, (1, 1, bk, 1))
    # padded kv columns must be masked out: they sit at positions >= Tk,
    # which the causal mask handles when offset keeps q rows < Tk; for the
    # non-causal case we pass an explicit window covering only real keys.
    out = flash_attention_pallas(
        qp, kp, vp, bq=bq, bk=bk, causal=causal, window=window,
        softcap=softcap, scale=scale, offset=offset,
        interpret=(backend == "interpret"))
    return out[:, :, :Tq]


def decode_attention(q, k, v, *, window=None, softcap=None, scale=None,
                     pos=None, backend: str | None = None) -> jax.Array:
    """Single-token decode over a KV cache: q (B, Hq, 1, D), kv (B, Hkv, S, D).
    ``pos`` = the query's absolute position; cache entries beyond it are
    masked (defaults to S − 1, full cache).  The Pallas path reuses the flash
    kernel with offset = pos (flash-decoding style streaming); a *traced*
    pos requires the ref path (the kernel offset is static)."""
    backend = backend or default_backend()
    if backend == "ref" or (pos is not None and not isinstance(pos, int)):
        return R.decode_attention_ref(q, k, v, window=window,
                                      softcap=softcap, scale=scale, pos=pos)
    S = k.shape[2]
    off = pos if pos is not None else S - 1
    return flash_attention(q, k, v, causal=True, window=window,
                           softcap=softcap, scale=scale, offset=off,
                           backend=backend)


def ssm_scan(x, dt, A, B, C, D, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return R.selective_scan_ref(x, dt, A, B, C, D)
    Bt, L, Dm = x.shape
    bd = min(128, Dm)
    bl = min(128, L)
    assert Dm % bd == 0 and L % bl == 0
    return ssm_scan_pallas(x, dt, A, B, C, D, bd=bd, bl=bl,
                           interpret=(backend == "interpret"))


def rwkv6(r, k, v, w, u, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        return R.rwkv6_ref(r, k, v, w, u)
    T = r.shape[2]
    bt = min(64, T)
    assert T % bt == 0
    return rwkv6_pallas(r, k, v, w, u, bt=bt,
                        interpret=(backend == "interpret"))
