"""Model-graph → LEGO workload lowering (the config→workload contract).

One lowering *row* is ``(kind, dims, repeat, nontensor)``:

``kind``
    ``"gemm"`` | ``"conv"`` | ``"dwconv"`` | ``"attn_qk"`` | ``"attn_pv"``
    — the LEGO workload the row maps onto
    (:func:`repro.core.workload.gemm` / :func:`~repro.core.workload.conv2d`
    / :func:`~repro.core.workload.depthwise_conv2d` /
    :func:`~repro.core.workload.attention_qk` /
    :func:`~repro.core.workload.attention_pv`);
``dims``
    that workload's iteration-dim sizes by name (``i/j/k`` for GEMM,
    ``n/oc/ic/oh/ow/kh/kw`` for conv, ``n/c/oh/ow/kh/kw`` for dwconv,
    ``b/m/n/d`` for the fused attention pair);
``repeat``
    how many times the shape executes end-to-end (layers × heads × experts ×
    batch folded in by the graph builder);
``nontensor``
    PPU element count per execution (softmax/norm/scan/token-shift) — LEGO
    runs these on-chip, the Gemmini baseline pays a DRAM round trip.

:func:`merge_rows` deduplicates identical ``(kind, dims, nontensor)`` shapes
by summing repeats, so the mapper never sees the same shape twice within one
model — MAC totals are preserved exactly.  The full contract (with a worked
Llama-4 example) is documented in ``docs/MODELS.md``.
"""

from __future__ import annotations

from typing import Iterable

from repro.configs import ARCH_IDS, get_config
from repro.models.common import ModelConfig

from .model_graph import PHASES, ModelGraph, build_model_graph

__all__ = ["Row", "merge_rows", "lower_model", "lower_zoo", "zoo_key",
           "unfuse_attention_rows", "has_attention_rows", "ATTENTION_KINDS"]

# (kind, dims, repeat, nontensor) — the evaluator/scoring row format
Row = tuple[str, dict[str, int], int, float]

# row kinds of the score-stationary fused attention pair
ATTENTION_KINDS = ("attn_qk", "attn_pv")


def has_attention_rows(rows: Iterable[Row]) -> bool:
    """True when the lowering kept the fused attn_qk/attn_pv pair."""
    return any(kind in ATTENTION_KINDS for kind, _, _, _ in rows)


def unfuse_attention_rows(rows: Iterable[Row]) -> list[Row]:
    """Rewrite fused ``attn_qk``/``attn_pv`` rows to the plain-GEMM lowering.

    This is the fallback for designs whose dataflow set has no spatial menu
    for the attention workloads: the batched ``b`` head×batch dim folds back
    into the repeat count and each stage becomes one GEMM per head —
    ``attn_qk(b,m,n,d)`` → ``gemm(i=m, j=n, k=d) × b`` and
    ``attn_pv(b,m,n,d)`` → ``gemm(i=m, j=d, k=n) × b``.  Total MACs and PPU
    elements are preserved exactly; P takes the HBM round trip this time
    (no residency credit — that is the whole point of the comparison).
    """
    out: list[Row] = []
    for kind, dims, rep, nt in rows:
        if kind == "attn_qk":
            b = dims["b"]
            out.append(("gemm", dict(i=dims["m"], j=dims["n"], k=dims["d"]),
                        rep * b, nt / b))
        elif kind == "attn_pv":
            b = dims["b"]
            out.append(("gemm", dict(i=dims["m"], j=dims["d"], k=dims["n"]),
                        rep * b, nt / b))
        else:
            out.append((kind, dims, rep, nt))
    return merge_rows(out)


def merge_rows(rows: Iterable[Row]) -> list[Row]:
    """Deduplicate rows with identical (kind, dims, nontensor) by summing
    repeats; first-appearance order is kept so lowering is deterministic."""
    merged: dict[tuple, list] = {}
    for kind, dims, rep, nt in rows:
        key = (kind, tuple(sorted(dims.items())), nt)
        if key in merged:
            merged[key][2] += rep
        else:
            merged[key] = [kind, dict(dims), rep, nt]
    return [tuple(v) for v in merged.values()]  # type: ignore[misc]


def lower_model(cfg: ModelConfig | str, *, seq: int = 512, batch: int = 1,
                phase: str = "prefill", reduced: bool = False,
                lm_head: bool = True,
                fused_attention: bool = True) -> list[Row]:
    """Lower one model (config object or ``repro.configs`` id) to merged
    workload rows for one execution phase.  ``fused_attention=False`` keeps
    the historical per-GEMM attention lowering (see
    :func:`unfuse_attention_rows`)."""
    if isinstance(cfg, str):
        cfg = get_config(cfg, reduced=reduced)
    graph = build_model_graph(cfg, seq=seq, batch=batch, phase=phase,
                              lm_head=lm_head,
                              fused_attention=fused_attention)
    return graph.lowered()


def zoo_key(name: str, phase: str, phases: Iterable[str]) -> str:
    """Zoo dict key for one (model, phase) variant: the bare model id when a
    single phase is swept, ``id@phase`` otherwise."""
    return name if len(tuple(phases)) == 1 else f"{name}@{phase}"


def lower_zoo(names: Iterable[str] | None = None, *, seq: int = 512,
              batch: int = 1, phases: Iterable[str] = ("prefill",),
              reduced: bool = False,
              lm_head: bool = True,
              fused_attention: bool = True) -> dict[str, list[Row]]:
    """Lower every named config once per phase: ``{key: rows}``.

    ``names=None`` lowers the whole assigned zoo (``repro.configs.ARCH_IDS``).
    """
    names = list(ARCH_IDS if names is None else names)
    phases = tuple(phases)
    for p in phases:
        if p not in PHASES:
            raise ValueError(f"unknown phase {p!r}; known: {PHASES}")
    zoo: dict[str, list[Row]] = {}
    for name in names:
        cfg = get_config(name, reduced=reduced)
        for phase in phases:
            zoo[zoo_key(name, phase, phases)] = lower_model(
                cfg, seq=seq, batch=batch, phase=phase, lm_head=lm_head,
                fused_attention=fused_attention)
    return zoo
