"""Model-graph → LEGO workload lowering (the config→workload contract).

One lowering *row* is ``(kind, dims, repeat, nontensor)``:

``kind``
    ``"gemm"`` | ``"conv"`` | ``"dwconv"`` — the LEGO workload the row maps
    onto (:func:`repro.core.workload.gemm` / :func:`~repro.core.workload.conv2d`
    / :func:`~repro.core.workload.depthwise_conv2d`);
``dims``
    that workload's iteration-dim sizes by name (``i/j/k`` for GEMM,
    ``n/oc/ic/oh/ow/kh/kw`` for conv, ``n/c/oh/ow/kh/kw`` for dwconv);
``repeat``
    how many times the shape executes end-to-end (layers × heads × experts ×
    batch folded in by the graph builder);
``nontensor``
    PPU element count per execution (softmax/norm/scan/token-shift) — LEGO
    runs these on-chip, the Gemmini baseline pays a DRAM round trip.

:func:`merge_rows` deduplicates identical ``(kind, dims, nontensor)`` shapes
by summing repeats, so the mapper never sees the same shape twice within one
model — MAC totals are preserved exactly.  The full contract (with a worked
Llama-4 example) is documented in ``docs/MODELS.md``.
"""

from __future__ import annotations

from typing import Iterable

from repro.configs import ARCH_IDS, get_config
from repro.models.common import ModelConfig

from .model_graph import PHASES, ModelGraph, build_model_graph

__all__ = ["Row", "merge_rows", "lower_model", "lower_zoo", "zoo_key"]

# (kind, dims, repeat, nontensor) — the evaluator/scoring row format
Row = tuple[str, dict[str, int], int, float]


def merge_rows(rows: Iterable[Row]) -> list[Row]:
    """Deduplicate rows with identical (kind, dims, nontensor) by summing
    repeats; first-appearance order is kept so lowering is deterministic."""
    merged: dict[tuple, list] = {}
    for kind, dims, rep, nt in rows:
        key = (kind, tuple(sorted(dims.items())), nt)
        if key in merged:
            merged[key][2] += rep
        else:
            merged[key] = [kind, dict(dims), rep, nt]
    return [tuple(v) for v in merged.values()]  # type: ignore[misc]


def lower_model(cfg: ModelConfig | str, *, seq: int = 512, batch: int = 1,
                phase: str = "prefill", reduced: bool = False,
                lm_head: bool = True) -> list[Row]:
    """Lower one model (config object or ``repro.configs`` id) to merged
    workload rows for one execution phase."""
    if isinstance(cfg, str):
        cfg = get_config(cfg, reduced=reduced)
    graph = build_model_graph(cfg, seq=seq, batch=batch, phase=phase,
                              lm_head=lm_head)
    return graph.lowered()


def zoo_key(name: str, phase: str, phases: Iterable[str]) -> str:
    """Zoo dict key for one (model, phase) variant: the bare model id when a
    single phase is swept, ``id@phase`` otherwise."""
    return name if len(tuple(phases)) == 1 else f"{name}@{phase}"


def lower_zoo(names: Iterable[str] | None = None, *, seq: int = 512,
              batch: int = 1, phases: Iterable[str] = ("prefill",),
              reduced: bool = False,
              lm_head: bool = True) -> dict[str, list[Row]]:
    """Lower every named config once per phase: ``{key: rows}``.

    ``names=None`` lowers the whole assigned zoo (``repro.configs.ARCH_IDS``).
    """
    names = list(ARCH_IDS if names is None else names)
    phases = tuple(phases)
    for p in phases:
        if p not in PHASES:
            raise ValueError(f"unknown phase {p!r}; known: {PHASES}")
    zoo: dict[str, list[Row]] = {}
    for name in names:
        cfg = get_config(name, reduced=reduced)
        for phase in phases:
            zoo[zoo_key(name, phase, phases)] = lower_model(
                cfg, seq=seq, batch=batch, phase=phase, lm_head=lm_head)
    return zoo
