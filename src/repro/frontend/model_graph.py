"""Workload-graph frontend: walk a :class:`~repro.models.common.ModelConfig`
into an operator graph of tensor ops (the paper's "diverse modern foundation
models" input, Fig. 12-style cross-model study).

Each :class:`OpNode` is one operator of the model — a projection GEMM, an
attention score/context GEMM stage, a MoE expert, an SSM depthwise conv, a
patch-embed conv — annotated with its LEGO workload kind
(:mod:`repro.core.workload`: ``gemm`` / ``conv2d`` / ``dwconv2d``), exact
iteration-dim sizes, a repeat count (layers × heads × experts) and the
non-tensor element count that runs on the PPUs (softmax, norms, token-shift,
selective scan).  A :class:`ModelGraph` is the ordered node sequence for one
execution *phase*:

``prefill``
    process ``seq`` tokens per sequence (plus any vision/audio prefix) — the
    throughput-bound regime spatial accelerators target;
``decode``
    one generated token per sequence against a ``seq``-token KV/state
    context — the latency-bound regime (GEMV-shaped workloads).

The graph covers every family in ``repro.configs``: dense/GQA/MQA attention
(``n_kv_heads`` shrinks the KV projection), sliding-window attention,
MoE routed + shared experts, Mamba SSM blocks (in/x/dt/out projections, the
depthwise causal conv as a real ``dwconv`` workload, selective scan on the
PPUs), RWKV-6 time/channel mix with token-shift and the decay LoRA,
encoder-decoder stacks with per-decoder-layer cross-attention, ViT-style
patch-embed stems for vision prefixes and the Whisper audio conv frontend.

Lowering to deduplicated ``(kind, dims, repeat, nontensor)`` rows — the
format consumed by :func:`repro.core.fusion.score_fused_design` and the DSE
evaluator — lives in :mod:`repro.frontend.lower`.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from math import isqrt

from repro.models.common import BlockSpec, ModelConfig

__all__ = ["OpNode", "ModelGraph", "build_model_graph", "PHASES"]

PHASES = ("prefill", "decode")

_PATCH = 14     # ViT patch edge for square vision prefixes (CLIP ViT-L/14)
_MEL_BINS = 80  # audio-frontend input channels (Whisper log-mel spectrogram)


@dataclass(frozen=True)
class OpNode:
    """One operator of the model graph.

    ``op`` is the semantic operator name (``qkv_proj``, ``attn_scores``,
    ``expert_up``, ``ssm_conv``, ...); ``kind`` is the LEGO workload it maps
    to (``gemm`` | ``conv`` | ``dwconv`` | ``attn_qk`` | ``attn_pv``, the
    row-kind strings of :mod:`repro.dse.evaluate`); ``dims`` uses that
    workload's iteration-dim names; ``nontensor`` elements run on the PPUs
    once per node execution.
    """

    name: str
    op: str
    kind: str
    dims: dict[str, int]
    repeat: int = 1
    nontensor: float = 0.0
    stage: str = "decoder"  # frontend | encoder | decoder | head

    @property
    def macs(self) -> int:
        """Total MACs including the repeat count."""
        m = 1
        for v in self.dims.values():
            m *= v
        return m * self.repeat

    def row(self) -> tuple[str, dict[str, int], int, float]:
        """This node as one un-merged lowering row."""
        return (self.kind, dict(self.dims), self.repeat, self.nontensor)


@dataclass(frozen=True)
class ModelGraph:
    """Ordered operator sequence of one model execution phase."""

    model: str
    phase: str
    seq: int
    batch: int
    nodes: tuple[OpNode, ...]

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def macs(self) -> int:
        return sum(n.macs for n in self.nodes)

    def nontensor(self) -> float:
        return sum(n.repeat * n.nontensor for n in self.nodes)

    def ops(self) -> Counter:
        """Node count per semantic operator name."""
        return Counter(n.op for n in self.nodes)

    def lowered(self) -> list[tuple[str, dict[str, int], int, float]]:
        """Deduplicated ``(kind, dims, repeat, nontensor)`` workload rows
        (identical shapes merge by summing repeats; MAC totals preserved)."""
        from .lower import merge_rows
        return merge_rows(n.row() for n in self.nodes)

    def summary(self, limit: int | None = None) -> str:
        """Human-readable node table (used by CLIs and docs/MODELS.md)."""
        hdr = (f"{'node':<28} {'kind':<7} {'rep':>6} {'MMACs':>10}  dims")
        lines = [f"== {self.model} [{self.phase}] seq={self.seq} "
                 f"batch={self.batch}: {self.n_nodes} nodes, "
                 f"{self.macs() / 1e9:.2f} GMACs ==", hdr, "-" * len(hdr)]
        for n in self.nodes[:limit]:
            dims = " ".join(f"{k}={v}" for k, v in n.dims.items())
            lines.append(f"{n.name:<28} {n.kind:<7} {n.repeat:>6} "
                         f"{n.macs / 1e6:>10.1f}  {dims}")
        if limit is not None and self.n_nodes > limit:
            lines.append(f"... ({self.n_nodes - limit} more)")
        return "\n".join(lines)


def build_model_graph(cfg: ModelConfig, *, seq: int = 512, batch: int = 1,
                      phase: str = "prefill",
                      lm_head: bool = True,
                      fused_attention: bool = True) -> ModelGraph:
    """Walk ``cfg`` into a :class:`ModelGraph` for one execution phase.

    ``fused_attention=True`` (default) emits every attention score/context
    stage as a fused ``attn_qk``/``attn_pv`` op pair over the batched
    attention workloads (:func:`repro.core.workload.attention_qk` /
    ``attention_pv``) with the head×batch axis as the batched ``b`` dim —
    the paper's score-stationary fusion where P = softmax(S) stays resident
    between the stages.  ``fused_attention=False`` keeps the historical
    per-GEMM lowering (one GEMM row per head×batch); designs whose dataflow
    set cannot map attention workloads fall back to it through
    :func:`repro.frontend.lower.unfuse_attention_rows` — both forms carry
    identical total MACs and PPU elements.
    """
    if phase not in PHASES:
        raise ValueError(f"phase must be one of {PHASES}, got {phase!r}")
    if seq < 1 or batch < 1:
        raise ValueError(f"seq/batch must be >= 1, got seq={seq} batch={batch}")
    has_attn = (cfg.is_encoder_decoder
                or any(s.kind == "attn" for s in cfg.layer_pattern))
    if has_attn and (cfg.n_kv_heads < 1
                     or cfg.n_heads % cfg.n_kv_heads != 0):
        # GQA shares each KV head across an integer group of query heads —
        # a non-divisible count has no defined grouping
        raise ValueError(
            f"GQA requires n_heads divisible by n_kv_heads >= 1, got "
            f"n_heads={cfg.n_heads} n_kv_heads={cfg.n_kv_heads} "
            f"in {cfg.name}")

    d, hd = cfg.d_model, cfg.hd
    prefill = phase == "prefill"
    pre = cfg.prefix_len
    S = seq + pre                      # prefill positions per sequence
    ctx = seq + pre                    # decode attention context length
    toks = (S if prefill else 1) * batch
    nodes: list[OpNode] = []

    def add(stage: str, layer: str, op: str, kind: str, dims: dict,
            rep: int = 1, nt: float = 0.0) -> None:
        nodes.append(OpNode(f"{layer}.{op}", op, kind,
                            {k: int(v) for k, v in dims.items()},
                            int(rep), float(nt), stage))

    # -- input stems (prefill only: prefixes and encoder inputs are cached
    # across decode steps) --------------------------------------------------
    if prefill and pre and not cfg.is_encoder_decoder:
        g = isqrt(pre)
        if g * g == pre:  # ViT-style square patch grid
            dims = dict(n=batch, oc=d, ic=3, oh=g, ow=g, kh=_PATCH, kw=_PATCH)
        else:             # 1-D prefix: framewise conv stem
            dims = dict(n=batch, oc=d, ic=3, oh=pre, ow=1, kh=3, kw=1)
        add("frontend", "stem", "patch_embed", "conv", dims)
    if prefill and cfg.is_encoder_decoder and cfg.enc_seq_len:
        E = cfg.enc_seq_len
        add("frontend", "stem", "audio_embed", "conv",
            dict(n=batch, oc=d, ic=_MEL_BINS, oh=2 * E, ow=1, kh=3, kw=1))
        add("frontend", "stem", "audio_embed_ds", "conv",
            dict(n=batch, oc=d, ic=d, oh=E, ow=1, kh=3, kw=1))

    # -- block emitters ------------------------------------------------------
    def attn_block(stage: str, layer: str, spec: BlockSpec, q_len: int,
                   kv_len: int, n_tok: int, rep: int,
                   causal_prefill: bool = True) -> None:
        eff = min(kv_len, spec.window) if spec.window else kv_len
        add(stage, layer, "qkv_proj", "gemm",
            dict(i=n_tok, j=(cfg.n_heads + 2 * cfg.n_kv_heads) * hd, k=d),
            rep)
        if prefill and causal_prefill:
            si, srep = q_len, cfg.n_heads * batch
        else:  # decode: one query row per sequence, batched on i
            si, srep = batch, cfg.n_heads
        if fused_attention:
            # score-stationary fused pair (paper Fig. 10 "Attention"): the
            # head×batch axis becomes the batched b dim, P = softmax(S) stays
            # resident between the stages (no HBM round trip for scores)
            add(stage, layer, "attn_scores", "attn_qk",
                dict(b=srep, m=si, n=eff, d=hd), rep,
                nt=srep * si * eff)                        # softmax on PPUs
            add(stage, layer, "attn_context", "attn_pv",
                dict(b=srep, m=si, n=eff, d=hd), rep)
        else:
            add(stage, layer, "attn_scores", "gemm", dict(i=si, j=eff, k=hd),
                rep * srep, nt=si * eff)                   # softmax on PPUs
            add(stage, layer, "attn_context", "gemm", dict(i=si, j=hd, k=eff),
                rep * srep)
        add(stage, layer, "out_proj", "gemm",
            dict(i=n_tok, j=d, k=cfg.n_heads * hd), rep,
            nt=n_tok * d)                                  # residual + norm

    def ffn_block(stage: str, layer: str, spec: BlockSpec, n_tok: int,
                  rep: int) -> None:
        n_up = 2 if cfg.glu else 1
        if spec.moe and cfg.n_experts:
            ff = cfg.d_ff_e
            active = cfg.top_k + cfg.n_shared_experts
            add(stage, layer, "router", "gemm",
                dict(i=n_tok, j=cfg.n_experts, k=d), rep,
                nt=n_tok * cfg.n_experts)                  # top-k on PPUs
            add(stage, layer, "expert_up", "gemm", dict(i=n_tok, j=ff, k=d),
                rep * n_up * active)
            add(stage, layer, "expert_down", "gemm", dict(i=n_tok, j=d, k=ff),
                rep * active, nt=n_tok * d)
        else:
            add(stage, layer, "ffn_up", "gemm",
                dict(i=n_tok, j=cfg.d_ff, k=d), rep * n_up)
            add(stage, layer, "ffn_down", "gemm",
                dict(i=n_tok, j=d, k=cfg.d_ff), rep, nt=n_tok * d)

    def mamba_block(stage: str, layer: str, n_tok: int, steps: int,
                    rep: int) -> None:
        di, dtr, ds = cfg.d_inner, cfg.dtr, cfg.d_state
        add(stage, layer, "ssm_in_proj", "gemm", dict(i=n_tok, j=2 * di, k=d),
            rep)
        add(stage, layer, "ssm_conv", "dwconv",   # depthwise causal conv1d
            dict(n=batch, c=di, oh=steps, ow=1, kh=cfg.d_conv, kw=1), rep)
        add(stage, layer, "ssm_x_proj", "gemm",
            dict(i=n_tok, j=dtr + 2 * ds, k=di), rep)
        add(stage, layer, "ssm_dt_proj", "gemm", dict(i=n_tok, j=di, k=dtr),
            rep)
        add(stage, layer, "ssm_out_proj", "gemm", dict(i=n_tok, j=d, k=di),
            rep, nt=n_tok * di * (ds + 1))        # selective scan + gating

    def rwkv_block(stage: str, layer: str, n_tok: int, rep: int) -> None:
        dr = cfg.rwkv_decay_rank
        add(stage, layer, "rwkv_time_mix", "gemm", dict(i=n_tok, j=d, k=d),
            rep * 4, nt=n_tok * d)                # r/k/v/g + token-shift lerp
        add(stage, layer, "rwkv_decay_lora", "gemm", dict(i=n_tok, j=dr, k=d),
            rep)
        add(stage, layer, "rwkv_decay_proj", "gemm", dict(i=n_tok, j=d, k=dr),
            rep)
        add(stage, layer, "rwkv_out_proj", "gemm", dict(i=n_tok, j=d, k=d),
            rep, nt=2 * n_tok * d)                # wkv scan + group norm
        add(stage, layer, "rwkv_channel_up", "gemm",
            dict(i=n_tok, j=cfg.d_ff, k=d), rep, nt=n_tok * d)  # token-shift
        add(stage, layer, "rwkv_channel_down", "gemm",
            dict(i=n_tok, j=d, k=cfg.d_ff), rep)

    # -- decoder stack: the layer pattern × n_periods ------------------------
    for i, spec in enumerate(cfg.layer_pattern):
        layer, rep = f"dec{i}", cfg.n_periods
        if spec.kind == "attn":
            attn_block("decoder", layer, spec, S, ctx, toks, rep)
        elif spec.kind == "mamba":
            mamba_block("decoder", layer, toks, S if prefill else 1, rep)
        elif spec.kind == "rwkv":
            rwkv_block("decoder", layer, toks, rep)
        else:
            raise ValueError(f"unknown block kind {spec.kind!r} "
                             f"in {cfg.name}")
        if spec.kind in ("attn", "mamba"):  # rwkv carries its channel mix
            ffn_block("decoder", layer, spec, toks, rep)

    # -- encoder stack + per-decoder-layer cross-attention -------------------
    if cfg.is_encoder_decoder and cfg.n_enc_layers and cfg.enc_seq_len:
        E, enc_toks = cfg.enc_seq_len, cfg.enc_seq_len * batch
        enc_spec = cfg.layer_pattern[0]
        if prefill:  # the encoder runs once; decode reuses its states
            attn_block("encoder", "enc", enc_spec, E, E, enc_toks,
                       cfg.n_enc_layers)
            ffn_block("encoder", "enc", enc_spec, enc_toks, cfg.n_enc_layers)
        n_dec = cfg.n_layers
        add("decoder", "xattn", "cross_q_proj", "gemm",
            dict(i=toks, j=cfg.n_heads * hd, k=d), n_dec)
        if prefill:  # cross K/V computed once per layer, cached for decode
            add("decoder", "xattn", "cross_kv_proj", "gemm",
                dict(i=enc_toks, j=2 * cfg.n_kv_heads * hd, k=d), n_dec)
        si, srep = (S, cfg.n_heads * batch) if prefill else (batch,
                                                            cfg.n_heads)
        if fused_attention:
            add("decoder", "xattn", "cross_scores", "attn_qk",
                dict(b=srep, m=si, n=E, d=hd), n_dec, nt=srep * si * E)
            add("decoder", "xattn", "cross_context", "attn_pv",
                dict(b=srep, m=si, n=E, d=hd), n_dec)
        else:
            add("decoder", "xattn", "cross_scores", "gemm",
                dict(i=si, j=E, k=hd), n_dec * srep, nt=si * E)
            add("decoder", "xattn", "cross_context", "gemm",
                dict(i=si, j=hd, k=E), n_dec * srep)
        add("decoder", "xattn", "cross_out_proj", "gemm",
            dict(i=toks, j=d, k=cfg.n_heads * hd), n_dec, nt=toks * d)

    # -- LM head over the text positions -------------------------------------
    if lm_head:
        out_toks = (seq if prefill else 1) * batch
        add("head", "head", "lm_head", "gemm",
            dict(i=out_toks, j=cfg.vocab_size, k=d))

    return ModelGraph(model=cfg.name, phase=phase, seq=seq, batch=batch,
                      nodes=tuple(nodes))
