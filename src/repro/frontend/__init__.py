"""Model-zoo frontend: ``repro.configs`` specs → operator graphs → LEGO
tensor workloads.

``model_graph`` — :func:`build_model_graph` walks a
:class:`~repro.models.common.ModelConfig` (attention incl. GQA/MQA, MoE
experts, SSM scan, RWKV token-shift, enc-dec cross-attention, conv stems)
into an :class:`OpNode` graph per execution phase (prefill / decode).

``lower`` — :func:`lower_model` / :func:`lower_zoo` turn graphs into the
deduplicated ``(kind, dims, repeat, nontensor)`` rows that
:func:`repro.core.fusion.score_fused_design` and the DSE evaluator consume.
"""

from .lower import (ATTENTION_KINDS, Row, has_attention_rows, lower_model,
                    lower_zoo, merge_rows, unfuse_attention_rows, zoo_key)
from .model_graph import PHASES, ModelGraph, OpNode, build_model_graph

__all__ = [
    "OpNode", "ModelGraph", "build_model_graph", "PHASES",
    "Row", "merge_rows", "lower_model", "lower_zoo", "zoo_key",
    "ATTENTION_KINDS", "has_attention_rows", "unfuse_attention_rows",
]
