from .sharding import (AxisNames, choose_axes, logical_to_spec, named_sharding,
                       shard_params_spec, with_constraint)
