"""Divisibility-aware declarative sharding (DESIGN.md §5).

Logical axis names decouple model code from the physical mesh:

  * ``batch``  → ("pod", "data")      pure DP across pods, DP/FSDP within
  * ``fsdp``   → ("data",)            parameter/optimizer sharding
  * ``tensor`` → ("model",)           TP / EP
  * ``seq``    → ("data", "model")    sequence sharding for long-context
  * ``expert`` → ("model",)           expert parallelism
  * ``none``   → replicated

``logical_to_spec`` resolves a tuple of logical names against a concrete
mesh, *dropping* (a) axes not present in the mesh (a single-pod mesh has no
"pod") and (b) axes whose size does not divide the dim — the fallback that
makes all 40 (arch × shape) dry-run cells shardable without per-arch cases
(e.g. kv_heads = 2 < model = 16 falls back to partial or no sharding).
"""

from __future__ import annotations

import re
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PROFILES: dict[str, dict[str, tuple[str, ...]]] = {
    # Megatron-style: TP over "model", FSDP over "data", DP across pods.
    "tp": {
        "batch": ("pod", "data"),
        "fsdp": ("data",),
        "tensor": ("model",),
        "seq": ("data", "model"),
        "seq_model": ("model",),
        "expert": ("model",),
        "vocab": ("model",),
        "none": (),
    },
    # ZeRO-3: batch over the whole mesh, params fully sharded, no TP — trades
    # per-layer activation all-reduces (O(B·T·d), huge at 1M tokens/step)
    # for per-layer parameter all-gathers (O(params/layer)).  The §Perf
    # winner for the small-d dense models' train cells.
    "fsdp": {
        "batch": ("pod", "data", "model"),
        "fsdp": ("data", "model"),
        "tensor": (),
        "seq": ("data", "model"),
        "seq_model": ("model",),
        "expert": ("model",),
        "vocab": ("model",),
        "none": (),
    },
}

LOGICAL_RULES: dict[str, tuple[str, ...]] = dict(PROFILES["tp"])


def set_profile(name: str) -> None:
    """Switch the global sharding profile ("tp" | "fsdp")."""
    LOGICAL_RULES.clear()
    LOGICAL_RULES.update(PROFILES[name])


@dataclass(frozen=True)
class AxisNames:
    batch: tuple[str, ...] = ("pod", "data")
    fsdp: str = "data"
    tensor: str = "model"


def choose_axes(dim_size: int, logical: str, mesh: Mesh) -> tuple[str, ...]:
    """Physical axes for one dim: greedily keep the prefix of the rule's
    axes that exists in the mesh and whose product divides ``dim_size``."""
    chosen: list[str] = []
    prod = 1
    for ax in LOGICAL_RULES[logical]:
        if ax not in mesh.axis_names:
            continue
        size = mesh.shape[ax]
        if dim_size % (prod * size) == 0:
            chosen.append(ax)
            prod *= size
    return tuple(chosen)


def logical_to_spec(logical_axes: tuple[str, ...], shape: tuple[int, ...],
                    mesh: Mesh) -> P:
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    used: set[str] = set()
    entries = []
    for name, dim in zip(logical_axes, shape):
        axes = tuple(a for a in choose_axes(dim, name, mesh) if a not in used)
        # re-check divisibility after dedup
        prod = 1
        keep = []
        for a in axes:
            if dim % (prod * mesh.shape[a]) == 0:
                keep.append(a)
                prod *= mesh.shape[a]
        used.update(keep)
        if not keep:
            entries.append(None)
        elif len(keep) == 1:
            entries.append(keep[0])
        else:
            entries.append(tuple(keep))
    return P(*entries)


def named_sharding(mesh: Mesh, logical_axes: tuple[str, ...],
                   shape: tuple[int, ...]) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, shape, mesh))


def with_constraint(x: jax.Array, mesh: Mesh | None,
                    logical_axes: tuple[str, ...]) -> jax.Array:
    """sharding_constraint against logical axes (no-op without a mesh)."""
    if mesh is None or mesh.size == 1:
        return x
    spec = logical_to_spec(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter sharding rules (matched by param-path suffix)
# ---------------------------------------------------------------------------

# ordered (regex, logical axes for the trailing dims) — first match wins.
# Params are layer-stacked: a leading scan dim is always replicated.
PARAM_RULES: list[tuple[str, tuple[str, ...]]] = [
    (r"embed/table", ("vocab", "fsdp")),
    (r"lm_head/w", ("fsdp", "vocab")),
    (r"(wq|wk|wv|wkv|in_proj|up|gate|w_up|w_gate|rkvwg|qkv)/w", ("fsdp", "tensor")),
    (r"(wo|down|w_down|out_proj)/w", ("tensor", "fsdp")),
    (r"experts/(w_up|w_gate)", ("expert", "fsdp", "tensor")),
    (r"experts/w_down", ("expert", "tensor", "fsdp")),
    (r"router/w", ("fsdp", "none")),
    (r"(conv1d)/w", ("none", "tensor")),
    (r"(A_log|dt_proj|x_proj|ssm_norm)/?.*", ("tensor", "none")),
    (r"(time_decay|time_first|u)$", ("none", "none")),
    (r".*(scale|bias|norm).*", ("none",)),
]


def _logical_for(path: str, ndim: int) -> tuple[str, ...]:
    for pat, logical in PARAM_RULES:
        if re.search(pat, path):
            if len(logical) > ndim:
                logical = logical[-ndim:]
            pad = ("none",) * (ndim - len(logical))
            return pad + tuple(logical)
    return ("none",) * ndim


def shard_params_spec(params, mesh: Mesh):
    """PartitionSpec pytree for a parameter pytree (path-rule matched)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def spec_of(path, leaf):
        pstr = "/".join(_key_str(k) for k in path)
        logical = _logical_for(pstr, leaf.ndim)
        return logical_to_spec(logical, leaf.shape, mesh)

    specs = {tuple(path): spec_of(path, leaf) for path, leaf in flat}
    return jax.tree_util.tree_map_with_path(
        lambda p, leaf: specs[tuple(p)], params)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
